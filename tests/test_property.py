"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import objective, reference
from repro.core.mapping import block_placement
from repro.core.topology import balanced_tree
from repro.graph.graph import from_edges, permute


def _graph_strategy(draw, max_n=24):
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(n, 3 * n))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    keep = u != v
    if not keep.any():
        v = (u + 1) % n
        keep = np.ones_like(u, dtype=bool)
    w = rng.uniform(0.1, 4.0, m).astype(np.float32)
    nw = rng.uniform(0.1, 3.0, n).astype(np.float32)
    return from_edges(n, u[keep], v[keep], w[keep], nw), seed


@st.composite
def graphs(draw, max_n=24):
    """Random symmetric weighted graphs (the composite strategy the
    docstring promises; ``graph_and_part`` composes a topology on top)."""
    g, _seed = _graph_strategy(draw, max_n)
    return g


@st.composite
def graph_and_part(draw):
    g, seed = _graph_strategy(draw)
    branching = draw(st.sampled_from([(2, 2), (4,), (2, 3), (2, 2, 2)]))
    topo = balanced_tree(branching)
    rng = np.random.default_rng(seed + 1)
    part = rng.integers(0, topo.k, g.n_nodes)
    return g, topo, part


@given(graph_and_part())
@settings(max_examples=40, deadline=None)
def test_jax_objective_equals_oracle(gtp):
    g, topo, part = gtp
    br = objective.makespan_tree(
        jnp.asarray(part, jnp.int32), jnp.asarray(g.senders),
        jnp.asarray(g.receivers), jnp.asarray(g.edge_weight),
        jnp.asarray(g.node_weight), jnp.asarray(topo.subtree),
        jnp.asarray(topo.F_l), k=topo.k)
    m_ref, comp_ref, comm_ref = reference.makespan_ref(part, g, topo)
    np.testing.assert_allclose(np.asarray(br.comp), comp_ref, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(br.comm), comm_ref, rtol=1e-3,
                               atol=1e-3)


@given(graph_and_part())
@settings(max_examples=25, deadline=None)
def test_makespan_lower_bound_and_scaling(gtp):
    """M(P) >= max-bin compute; scaling all edge weights by c scales every
    link load by c (linearity of comm in the edge weights)."""
    g, topo, part = gtp
    m_ref, comp_ref, comm_ref = reference.makespan_ref(part, g, topo)
    assert m_ref >= comp_ref.max() - 1e-5
    g2 = type(g)(g.n_nodes, g.senders, g.receivers, g.edge_weight * 2.0,
                 g.node_weight, g.offsets)
    _, _, comm2 = reference.makespan_ref(part, g2, topo)
    np.testing.assert_allclose(comm2, 2.0 * comm_ref, rtol=1e-5)


@given(graph_and_part())
@settings(max_examples=25, deadline=None)
def test_vertex_relabeling_invariance(gtp):
    """Relabeling graph vertices (and permuting the assignment with them)
    leaves the objective unchanged."""
    g, topo, part = gtp
    rng = np.random.default_rng(0)
    perm = rng.permutation(g.n_nodes)
    g2 = permute(g, perm)
    part2 = np.empty_like(part)
    part2[perm] = part
    m1, _, c1 = reference.makespan_ref(part, g, topo)
    m2, _, c2 = reference.makespan_ref(part2, g2, topo)
    assert abs(m1 - m2) < 1e-4
    np.testing.assert_allclose(np.sort(c1), np.sort(c2), rtol=1e-5)


@given(st.integers(2, 10), st.integers(10, 60), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_block_placement_is_permutation(k, n, seed):
    rng = np.random.default_rng(seed)
    part = rng.integers(0, k, n)
    pl = block_placement(part, k)
    # perm maps each vertex into its bin's block
    assert pl.perm.shape == (n,)
    assert len(set(pl.perm.tolist())) == n           # injective
    for v in range(n):
        assert pl.bin_of_row[pl.perm[v]] == part[v]


@given(graphs())
@settings(max_examples=25, deadline=None)
def test_graph_strategy_invariants(g):
    """Graphs drawn from the strategy satisfy the arc-list contract:
    symmetric arcs, CSR-sorted senders, degrees consistent with offsets."""
    assert g.senders.shape == g.receivers.shape == g.edge_weight.shape
    assert g.n_arcs % 2 == 0
    assert (np.diff(g.senders) >= 0).all()           # CSR order
    assert g.degrees().sum() == g.n_arcs
    fwd = set(zip(g.senders.tolist(), g.receivers.tolist()))
    assert all((v, u) in fwd for u, v in fwd)        # symmetric


@st.composite
def traffic_tree_and_candidates(draw):
    """Random symmetric traffic matrix x random machine tree x a batch of
    random device->bin permutations (the mapping-search regime)."""
    branching = draw(st.sampled_from([(2, 2), (4,), (2, 3), (2, 2, 2),
                                      (3, 2)]))
    topo = balanced_tree(branching)
    d = topo.k
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    T = rng.uniform(0, 4, (d, d)) * (rng.uniform(0, 1, (d, d)) > 0.3)
    T = np.triu(T, 1)
    T = T + T.T
    n_cand = draw(st.integers(1, 6))
    cands = np.stack([rng.permutation(d) for _ in range(n_cand)])
    return topo, T, cands


@given(traffic_tree_and_candidates())
@settings(max_examples=30, deadline=None)
def test_batched_permutation_scorer_agrees_with_fallbacks(ttc):
    """The batched permutation scorer, the vmap(makespan_tree) fallback and
    the per-candidate makespan_of_device_map must agree per candidate."""
    from repro.core import mapping
    topo, T, cands = ttc
    batched = mapping.score_device_maps(T, topo, cands)
    looped = np.asarray([mapping.makespan_of_device_map(T, topo, c)
                         for c in cands])
    s, r, w = mapping._traffic_edges(T)
    br = objective.makespan_tree_batch(
        jnp.asarray(cands, jnp.int32), s, r, w,
        jnp.zeros(T.shape[0], jnp.float32), jnp.asarray(topo.subtree),
        jnp.asarray(topo.F_l), k=topo.k)
    vmapped = np.asarray(br.comm_max)
    scale = max(float(np.abs(looped).max()), 1.0)
    np.testing.assert_allclose(batched, looped, rtol=1e-4,
                               atol=1e-5 * scale)
    np.testing.assert_allclose(vmapped, looped, rtol=1e-4,
                               atol=1e-5 * scale)


@given(graph_and_part())
@settings(max_examples=25, deadline=None)
def test_uniform_speeds_reproduce_todays_makespan_exactly(gtp):
    """Heterogeneous-PE objective, degenerate case: all-ones speeds must
    reproduce the speed-free makespan EXACTLY (x / 1.0 is an IEEE no-op),
    in both the oracle and the jitted objective — so uniform machine
    presets stay bit-for-bit on the historical numbers."""
    g, topo, part = gtp
    ones = np.ones(topo.k, dtype=np.float32)
    m0, comp0, comm0 = reference.makespan_ref(part, g, topo)
    m1, comp1, comm1 = reference.makespan_ref(part, g, topo, speed=ones)
    assert m0 == m1
    np.testing.assert_array_equal(comp0, comp1)
    np.testing.assert_array_equal(comm0, comm1)
    args = (jnp.asarray(part, jnp.int32), jnp.asarray(g.senders),
            jnp.asarray(g.receivers), jnp.asarray(g.edge_weight),
            jnp.asarray(g.node_weight), jnp.asarray(topo.subtree),
            jnp.asarray(topo.F_l))
    br0 = objective.makespan_tree(*args, k=topo.k)
    br1 = objective.makespan_tree(*args, k=topo.k, speed=jnp.asarray(ones))
    assert float(br0.makespan) == float(br1.makespan)
    np.testing.assert_array_equal(np.asarray(br0.comp),
                                  np.asarray(br1.comp))


@given(graph_and_part())
@settings(max_examples=25, deadline=None)
def test_capacity_normalized_objective_equals_oracle(gtp):
    """Random positive speeds: jitted capacity-normalized breakdown ==
    loop-based oracle with the same speeds."""
    g, topo, part = gtp
    rng = np.random.default_rng(g.n_nodes)
    speed = rng.uniform(0.25, 1.0, topo.k).astype(np.float32)
    m_ref, comp_ref, comm_ref = reference.makespan_ref(part, g, topo,
                                                       speed=speed)
    br = objective.makespan_tree(
        jnp.asarray(part, jnp.int32), jnp.asarray(g.senders),
        jnp.asarray(g.receivers), jnp.asarray(g.edge_weight),
        jnp.asarray(g.node_weight), jnp.asarray(topo.subtree),
        jnp.asarray(topo.F_l), k=topo.k, speed=jnp.asarray(speed))
    np.testing.assert_allclose(np.asarray(br.comp), comp_ref, rtol=1e-4,
                               atol=1e-4)
    assert abs(float(br.makespan) - m_ref) <= 1e-3 * max(1.0, m_ref)


@st.composite
def request_streams(draw):
    """Random serving workloads against a random-size paged pool: request
    (prompt, gen) lengths, staggered submit steps, slot/page-pool shapes
    sized so every request is feasible (infeasible ones are a submit()
    ValueError, pinned in tests/test_serving.py)."""
    page_size = draw(st.integers(1, 4))
    n_slots = draw(st.integers(1, 4))
    n_req = draw(st.integers(1, 10))
    reqs = [(draw(st.integers(1, 9)), draw(st.integers(1, 6)))
            for _ in range(n_req)]
    max_need = max(-(-(p + g) // page_size) for p, g in reqs)
    max_pages = draw(st.integers(max_need, max_need + 3))
    n_pages = draw(st.integers(max_need, max_need * n_slots + 4))
    seed = draw(st.integers(0, 2 ** 16))
    return page_size, n_slots, n_pages, max_pages, reqs, seed


@given(request_streams())
@settings(max_examples=60, deadline=None)
def test_scheduler_invariants_under_random_streams(stream):
    """The serving scheduler under random request streams: no page is ever
    owned by two live requests, pages in flight never exceed the pool,
    completed requests return every page to the free list, admitted
    requests never starve (first token exactly prompt_len steps after
    admission) and the whole stream drains within the token budget — all
    preserved under random mid-stream page re-placements."""
    from repro.serving import PagedKVCache, Request, Scheduler
    page_size, n_slots, n_pages, max_pages, reqs, seed = stream
    cache = PagedKVCache(n_pages, page_size, n_slots, max_pages)
    sched = Scheduler(cache)
    rng = np.random.default_rng(seed)
    submits = sorted(int(rng.integers(0, 4)) for _ in reqs)
    pending = [(s, Request(rid=i, prompt=np.zeros(p, np.int32),
                           max_new_tokens=g))
               for i, ((p, g), s) in enumerate(zip(reqs, submits))]
    # every step with active work advances >= 1 token; idle steps only
    # happen before the last submit arrives
    bound = sum(p + g for p, g in reqs) + max(submits) + 1
    step = 0
    while pending or sched.has_work():
        assert step <= bound, "scheduler failed to make progress"
        while pending and pending[0][0] <= step:
            sched.submit(pending.pop(0)[1], step=step)
        sched.admit(step)
        for si in sched.step_inputs():
            sched.advance(si.slot, step, 0 if si.needs_sample else None)
        sched.check_invariants()
        live = [p for v in cache.live_page_sets().values() for p in v]
        assert len(live) == len(set(live))           # no double ownership
        assert len(live) + cache.allocator.n_free == n_pages
        if rng.random() < 0.15:                      # placement mid-stream
            cache.apply_placement(rng.integers(0, 3, n_pages))
            sched.check_invariants()
        step += 1
    assert cache.allocator.n_free == n_pages         # full drain
    assert len(sched.completed) == len(reqs)
    for r in sched.completed:
        assert r.admit_step >= r.submit_step >= 0
        assert r.first_token_step - r.admit_step == r.prompt_len - 1
        assert r.done_step >= r.first_token_step
        assert len(r.generated) == r.max_new_tokens


@st.composite
def chaos_streams(draw):
    """Random request streams x random fault plans against the chaos
    harness (the real scheduler + cache; a manual seeded sweep of the
    same property runs in tests/test_resilience.py so CI covers it when
    hypothesis is absent)."""
    n_req = draw(st.integers(1, 8))
    reqs = [(draw(st.integers(1, 8)), draw(st.integers(1, 6)))
            for _ in range(n_req)]
    n_devices = draw(st.integers(2, 4))
    n_deaths = draw(st.integers(0, n_devices - 1))
    seed = draw(st.integers(0, 2 ** 16))
    return reqs, n_devices, n_deaths, seed


@given(chaos_streams())
@settings(max_examples=50, deadline=None)
def test_scheduler_invariants_under_fault_plans(chaos):
    """Scheduler invariants survive injected leaf deaths: no double page
    ownership (checked per step inside the harness), free + dead covers
    the drained pool, every request terminates DONE or FAILED, survivor
    token streams are bit-identical to the clean run, and requests whose
    whole lifecycle precedes the first death keep their exact TTFT."""
    from repro.resilience import ChaosHarness, FaultPlan
    reqs, n_devices, n_deaths, seed = chaos
    plan = (FaultPlan.random(seed, 40, n_devices, n_deaths=n_deaths)
            if n_deaths else None)

    def drive(p):
        h = ChaosHarness(n_pages=24, n_devices=n_devices, plan=p)
        for rid, (pl, gl) in enumerate(reqs):
            h.submit(rid, pl, gl)
        return h, h.run()

    h_clean, clean = drive(None)
    h, chaos_res = drive(plan)
    assert len(chaos_res.completed) + len(chaos_res.failed) == len(reqs)
    for rid, toks in chaos_res.completed.items():
        assert toks == clean.completed[rid]
    alloc = h.scheduler.cache.allocator
    assert alloc.n_free + alloc.n_dead == alloc.n_pages  # drained, no leak
    first_death = min((e.step for e in (plan.events if plan else ())
                       if e.kind == "leaf_death"), default=None)
    if first_death is not None:
        clean_done = {r.rid: r for r in h_clean.scheduler.completed}
        for r in h.scheduler.completed:
            if r.retries == 0 and r.done_step < first_death:
                assert (r.first_token_step
                        == clean_done[r.rid].first_token_step)
                assert r.done_step == clean_done[r.rid].done_step


@given(graphs(max_n=60), st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_device_coarsening_invariants(g, seed):
    """Device coarsening (coarsen_device): total node weight preserved at
    every level, edge weight conserved up to the contracted intra-cluster
    weight, node count strictly decreasing, and every fine_to_coarse a
    total surjective map. (Manual multi-seed twin:
    tests/test_device_vcycle.py, matching the existing fallback pattern.)"""
    from repro.core.coarsen import coarsen_device
    levels = coarsen_device(g, k=2, seed=seed, coarse_factor=1)
    for li in range(1, len(levels)):
        fg, cg = levels[li - 1].graph, levels[li].graph
        assert cg.n_nodes < fg.n_nodes
        np.testing.assert_allclose(cg.node_weight.sum(),
                                   fg.node_weight.sum(), rtol=1e-5)
        f2c = levels[li - 1].fine_to_coarse
        assert f2c.shape == (fg.n_nodes,)
        assert f2c.min() >= 0 and f2c.max() == cg.n_nodes - 1
        assert np.unique(f2c).size == cg.n_nodes      # surjective
        half = fg.senders < fg.receivers
        intra = fg.edge_weight[half & (f2c[fg.senders]
                                       == f2c[fg.receivers])].sum()
        np.testing.assert_allclose(
            cg.edge_weight[cg.senders < cg.receivers].sum(),
            fg.edge_weight[half].sum() - intra, rtol=1e-4, atol=1e-5)


@st.composite
def cache_workloads(draw):
    """Random embedding-cache workloads (table size, pool size, device
    count, lookup/update stream seed; a manual seeded sweep of the same
    property runs in tests/test_embed.py so CI covers it when hypothesis
    is absent)."""
    v = draw(st.integers(8, 60))
    n_cache = draw(st.integers(0, 10))
    n_devices = draw(st.integers(1, 5))
    policy = draw(st.sampled_from(["lru", "static"]))
    seed = draw(st.integers(0, 2 ** 16))
    return v, n_cache, n_devices, policy, seed


@given(cache_workloads())
@settings(max_examples=40, deadline=None)
def test_embed_cache_invariants(wl):
    """Hot-row cache under random lookup/update streams: no row lives in
    two shards, hits + misses == lookups after every call, eviction never
    loses a pending update (the flushed replicated table and accumulator
    bitwise-match the dense-update oracle), and the traffic matrix stays
    symmetric / zero-diagonal / finite."""
    from repro.embed import (HotRowCache, RowAccessStats,
                             ShardedEmbeddingTable, dense_row_update,
                             plan_shards)
    v, n_cache, n_devices, policy, seed = wl
    rng = np.random.default_rng(seed)
    e = 4
    stats = RowAccessStats(v)
    for _ in range(3):
        stats.record(rng.integers(0, v, (4, 3)))
    plan = plan_shards(stats, n_devices=n_devices)
    # no row in two shards: the assignment is a total function and the
    # device-contiguous permutation covers every row exactly once
    plan.check()
    assert np.array_equal(np.sort(plan.order), np.arange(v))
    table = jnp.asarray(rng.normal(0, 1, (v, e)).astype(np.float32))
    cache = HotRowCache(ShardedEmbeddingTable(table, plan),
                        n_cache=n_cache, policy=policy)
    cache.warm(stats.top_rows(n_cache))
    accum = jnp.zeros(v, jnp.float32)
    ref_tbl, ref_acc = table, jnp.zeros(v, jnp.float32)
    for _ in range(5):
        ids = rng.integers(0, v, int(rng.integers(1, 12)))
        vals = cache.lookup(ids)
        assert np.array_equal(np.asarray(vals), np.asarray(ref_tbl)[ids])
        rows = np.unique(ids)
        g = rng.normal(0, 1, (rows.shape[0], e)).astype(np.float32)
        accum = cache.apply_grads(rows, g, accum)
        gd = jnp.zeros((v, e), jnp.float32).at[jnp.asarray(rows)].set(
            jnp.asarray(g))
        ref_tbl, ref_acc = dense_row_update(ref_tbl, ref_acc, gd)
        assert cache.hits + cache.misses == cache.lookups
        cache.check_invariants()
    rep = cache.replicated()
    assert not cache.pending
    assert np.array_equal(np.asarray(rep), np.asarray(ref_tbl))
    assert np.array_equal(np.asarray(accum), np.asarray(ref_acc))


@given(st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_monotone_edge_addition(seed):
    """Adding an edge never decreases any link load (fixed partition)."""
    rng = np.random.default_rng(seed)
    n = 12
    topo = balanced_tree((2, 3))
    part = rng.integers(0, topo.k, n)
    u = rng.integers(0, n, 20)
    v = rng.integers(0, n, 20)
    keep = u != v
    g1 = from_edges(n, u[keep][:-1], v[keep][:-1])
    g2 = from_edges(n, u[keep], v[keep])
    _, _, c1 = reference.makespan_ref(part, g1, topo)
    _, _, c2 = reference.makespan_ref(part, g2, topo)
    assert (c2 - c1 >= -1e-5).all()
