"""Fault tolerance (ISSUE 8): deterministic fault plans, machine
degradation, page retirement, requeue/backoff recovery, chaos-stream
replay determinism, supervised training restarts, and checkpoint
tmp-dir hygiene (DESIGN.md §Fault-tolerance)."""
import json

import numpy as np
import pytest

from repro.core import machine as machine_lib
from repro.core.initial import initial_partition
from repro.graph.graph import from_edges
from repro.resilience import (ChaosHarness, DeviceFailure, FaultEvent,
                              FaultInjector, FaultPlan, parse_fault_plan,
                              run_chaos)
from repro.serving import PagedKVCache, Request, Scheduler
from repro.serving.kv_cache import PageAllocator


# ---------------------------------------------------------------------------
# Fault plans + injector
# ---------------------------------------------------------------------------

def test_fault_plan_sorted_and_indexed():
    plan = FaultPlan((FaultEvent(9, "leaf_death", 2),
                      FaultEvent(3, "straggler", 1, 0.5),
                      FaultEvent(3, "link_degrade", "dcn", 0.5)))
    assert [e.step for e in plan.events] == [3, 3, 9]
    assert len(plan.at(3)) == 2 and len(plan.at(4)) == 0
    assert plan.deaths() == (2,)


def test_fault_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(0, "meteor", 0)
    with pytest.raises(ValueError, match="factor"):
        FaultEvent(0, "straggler", 0, 1.5)
    with pytest.raises(ValueError, match="tree level by name"):
        FaultEvent(0, "link_degrade", 3)
    with pytest.raises(ValueError, match="step"):
        FaultEvent(-1, "leaf_death", 0)


def test_random_plan_deterministic_and_never_kills_all():
    p1 = FaultPlan.random(7, 50, 4, n_deaths=3)
    p2 = FaultPlan.random(7, 50, 4, n_deaths=3)
    assert p1.events == p2.events
    assert len(set(p1.deaths())) == 3 < 4
    with pytest.raises(ValueError, match="kill"):
        FaultPlan.random(0, 50, 4, n_deaths=4)


def test_parse_inline_and_json_round_trip(tmp_path):
    plan = parse_fault_plan("6:leaf_death:1,2:link_degrade:dcn:0.5")
    assert plan.events[0].kind == "link_degrade"
    assert plan.events[1].target == 1
    path = tmp_path / "plan.json"
    path.write_text(plan.to_json())
    again = parse_fault_plan(str(path))
    assert again.events == plan.events
    raw = json.loads(plan.to_json())
    assert {e["kind"] for e in raw["events"]} == {"leaf_death",
                                                 "link_degrade"}


def test_injector_fires_each_event_exactly_once():
    plan = FaultPlan((FaultEvent(2, "leaf_death", 0),
                      FaultEvent(5, "straggler", 1, 0.5)))
    inj = FaultInjector(plan)
    assert inj.fire(1) == []
    assert [e.step for e in inj.fire(3)] == [2]      # catches up past 2
    # a supervisor restart rewinds the step counter: the fired death
    # must NOT replay, or recovery would loop forever
    assert inj.fire(0) == []
    assert inj.fire(2) == []
    assert not inj.exhausted
    assert [e.kind for e in inj.fire(9)] == ["straggler"]
    assert inj.exhausted
    assert len(inj.history()) == 2


# ---------------------------------------------------------------------------
# MachineSpec.degrade
# ---------------------------------------------------------------------------

def test_degrade_masks_leaves_and_renormalizes():
    spec = machine_lib.resolve("tpu-mixed-32")
    deg = spec.degrade([FaultEvent(0, "leaf_death", 3)])
    assert deg.n_alive == spec.n_devices - 1
    topo = deg.topology()
    assert len(topo.compute_bins) == deg.n_alive
    speed = np.asarray(topo.bin_speed)
    assert (speed > 0).all() and speed.max() == pytest.approx(1.0)
    # degradation is cumulative and idempotent per leaf
    deg2 = deg.degrade([FaultEvent(1, "leaf_death", 3),
                        FaultEvent(1, "leaf_death", 7)])
    assert deg2.n_alive == spec.n_devices - 2
    assert 3 in deg2.dead_leaves and 7 in deg2.dead_leaves


def test_degrade_invalidates_placement_cache_token():
    spec = machine_lib.resolve("tpu_v5e-256")
    deg = spec.degrade([FaultEvent(0, "leaf_death", 0)])
    assert deg.cache_token() != spec.cache_token()
    slow = spec.degrade([FaultEvent(0, "link_degrade", "dcn", 0.5)])
    assert slow.cache_token() != spec.cache_token()
    assert slow.cache_token() != deg.cache_token()


def test_degrade_link_repricing_cumulative():
    spec = machine_lib.resolve("tpu_v5e-256")
    base = spec.tree()
    half = spec.degrade([FaultEvent(0, "link_degrade", "dcn", 0.5)])
    quarter = half.degrade([FaultEvent(1, "link_degrade", "dcn", 0.5)])
    # dcn is level 0; halving its bandwidth doubles its per-byte cost
    assert half.tree().F_l[0] == pytest.approx(2 * base.F_l[0])
    assert quarter.tree().F_l[0] == pytest.approx(4 * base.F_l[0])
    # repricing one level never cheapens another
    assert half.tree().F_l[-1] == pytest.approx(base.F_l[-1])


def test_degrade_refuses_to_kill_everything():
    spec = machine_lib.resolve("torus-2d")
    with pytest.raises(ValueError, match="torus"):
        spec.degrade([FaultEvent(0, "leaf_death", 0)])
    small = machine_lib.MachineSpec(
        name="pair", levels=(machine_lib.Level("link", 2, 100.0),),
        mesh_shape=(2,), axes=("data",))
    with pytest.raises(ValueError):
        small.degrade([FaultEvent(0, "leaf_death", 0),
                       FaultEvent(0, "leaf_death", 1)])


def test_zero_capacity_bin_never_reaches_partitioner():
    """Dead leaves must be MASKED, not zero-speed: the partitioner and
    the page mapper both refuse a zero-capacity bin outright."""
    topo = machine_lib.resolve("tpu-mixed-32").degrade(
        [FaultEvent(0, "leaf_death", 0)]).topology()
    g = from_edges(8, np.array([0, 1, 2]), np.array([1, 2, 3]))
    part = initial_partition(g, topo)                # masked topo: fine
    assert part.max() < len(topo.compute_bins)
    import dataclasses as dc
    bad = dc.replace(topo, bin_speed=np.asarray(topo.bin_speed).copy())
    bad.bin_speed[0] = 0.0
    with pytest.raises(ValueError, match="zero-capacity"):
        initial_partition(g, bad)


# ---------------------------------------------------------------------------
# Page retirement + scheduler recovery
# ---------------------------------------------------------------------------

def test_allocator_retire_accounting():
    al = PageAllocator(8)
    held = al.alloc(3)
    with pytest.raises(ValueError, match="release its slot"):
        al.retire(held[:1])
    al.retire([6, 7])
    assert al.n_usable == 6 and al.n_dead == 2
    with pytest.raises(ValueError, match="already retired"):
        al.retire([6])
    # retired pages never come back through alloc
    al.free(held)
    got = al.alloc(al.n_free)
    assert not set(got) & {6, 7}
    assert al.n_free == 0


def test_cache_fail_pages_zeroes_traffic():
    cache = PagedKVCache(n_pages=8, page_size=2, n_slots=2,
                         max_pages_per_req=4)
    cache.assign_slot(0, 8)
    cache.record_access({0: 8})
    assert cache.traffic.sum() > 0
    cache.release_slot(0)
    dead = [0, 1]
    cache.fail_pages(dead)
    assert cache.traffic[dead, :].sum() == 0
    assert cache.traffic[:, dead].sum() == 0
    assert cache.access_count[dead].sum() == 0
    cache.check_invariants()


def test_submit_rejects_infeasible_on_degraded_pool():
    cache = PagedKVCache(n_pages=8, page_size=2, n_slots=2,
                         max_pages_per_req=8)
    cache.fail_pages(list(range(5)))                 # 3 usable pages
    sched = Scheduler(cache)
    with pytest.raises(ValueError, match="never be admitted"):
        sched.submit(Request(rid=0, prompt=np.zeros(6, np.int32),
                             max_new_tokens=2))      # needs 4 pages


def test_admit_fails_infeasible_head_instead_of_blocking():
    """A queued request the shrunken pool can never fit must FAIL at
    admission — never head-block the feasible requests behind it."""
    cache = PagedKVCache(n_pages=8, page_size=2, n_slots=2,
                         max_pages_per_req=8)
    sched = Scheduler(cache)
    sched.submit(Request(rid=0, prompt=np.zeros(6, np.int32),
                         max_new_tokens=2), step=0)  # needs 4 pages
    sched.submit(Request(rid=1, prompt=np.zeros(2, np.int32),
                         max_new_tokens=2), step=0)  # needs 2 pages
    cache.fail_pages([0, 1, 2, 3, 4])                # 3 usable left
    admitted = sched.admit(step=1)
    assert [r.rid for r in admitted] == [1]
    assert [r.rid for r in sched.failed] == [0]
    assert "infeasible after degrade" in sched.failed[0].fail_reason


def test_handle_leaf_death_requeues_with_backoff_then_fails():
    cache = PagedKVCache(n_pages=8, page_size=2, n_slots=2,
                         max_pages_per_req=4)
    sched = Scheduler(cache)
    sched.submit(Request(rid=0, prompt=np.zeros(2, np.int32),
                         max_new_tokens=2), step=0)
    sched.admit(0)
    victim_page = cache.slot_pages[0][0]
    out = sched.handle_leaf_death([victim_page], step=3, max_retries=2)
    req = out["requeued"][0]
    assert req.retries == 1 and req.replay_gen == 0
    assert req.not_before == 3 + 2                   # backoff_base * 2^0
    assert cache.allocator.n_dead == 1
    # exhaust the retry budget: next death on its pages is terminal
    sched.admit(req.not_before)
    req.retries = 2
    page = cache.slot_pages[req.slot][0]
    out = sched.handle_leaf_death([page], step=9, max_retries=2)
    assert out["requeued"] == [] and out["failed"] == [req]
    assert "retries exhausted" in req.fail_reason


# ---------------------------------------------------------------------------
# Chaos harness: replay determinism without JAX
# ---------------------------------------------------------------------------

def test_chaos_harness_matches_clean_run():
    plan = FaultPlan((FaultEvent(4, "leaf_death", 1),))
    clean = run_chaos(8, seed=0)
    chaos = run_chaos(8, seed=0, plan=plan)
    assert not chaos.failed
    assert chaos.retried >= 1
    assert chaos.completed == clean.completed        # bit-identical
    assert chaos.recoveries[0]["n_alive"] == 3


def test_chaos_harness_seeded_sweep():
    """The manual stand-in for the Hypothesis property (hypothesis is an
    optional dependency): random plans x random streams, survivors always
    bit-identical, every request DONE or FAILED, pool never leaks."""
    for seed in range(25):
        plan = FaultPlan.random(seed, 40, 4, n_deaths=2)
        clean = run_chaos(6, seed=seed, n_pages=24)
        h = ChaosHarness(n_pages=24, plan=plan)
        rng = np.random.default_rng(seed)
        for rid in range(6):
            h.submit(rid, int(rng.integers(2, 9)), int(rng.integers(1, 9)))
        chaos = h.run()
        for rid, toks in chaos.completed.items():
            assert toks == clean.completed[rid], (seed, rid)
        assert len(chaos.completed) + len(chaos.failed) == 6
        alloc = h.scheduler.cache.allocator
        assert alloc.n_free + alloc.n_dead == alloc.n_pages


def test_chaos_unaffected_requests_keep_ttft():
    """Requests whose whole lifecycle precedes the death are untouched:
    identical TTFT and completion step as the clean run."""
    plan = FaultPlan((FaultEvent(30, "leaf_death", 0),))
    h = ChaosHarness(plan=plan)
    rng = np.random.default_rng(2)
    for rid in range(8):
        h.submit(rid, int(rng.integers(2, 9)), int(rng.integers(1, 9)))
    h.run()
    clean_h = ChaosHarness()
    rng = np.random.default_rng(2)
    for rid in range(8):
        clean_h.submit(rid, int(rng.integers(2, 9)),
                       int(rng.integers(1, 9)))
    clean_h.run()
    cdone = {r.rid: r for r in clean_h.scheduler.completed}
    for r in h.scheduler.completed:
        if r.retries == 0 and r.done_step < 30:
            assert r.first_token_step == cdone[r.rid].first_token_step
            assert r.done_step == cdone[r.rid].done_step


# ---------------------------------------------------------------------------
# Training: supervised restart + checkpoint hygiene
# ---------------------------------------------------------------------------

def _toy_step(params, opt_state, batch):
    g = float(batch["x"].mean())
    params = {"w": params["w"] - 0.1 * g}
    return params, opt_state, {"loss": float(params["w"].sum()) ** 2}


def _toy_factory(start):
    def gen():
        i = start
        while True:
            yield {"x": np.full((4,), float(i + 1), np.float32)}
            i += 1
    return gen()


def test_supervised_restart_preserves_loss_trajectory(tmp_path):
    """THE training acceptance check: a leaf death mid-run, restored from
    the newest checkpoint onto the degraded machine, reproduces the
    uninterrupted loss trajectory exactly."""
    import jax.numpy as jnp
    from repro.train import loop
    params0 = {"w": jnp.ones((3,))}
    cfg = loop.LoopConfig(total_steps=12, ckpt_every=4, log_every=100)
    _, _, clean = loop.run(_toy_step, dict(params0), None,
                           _toy_factory(0), cfg)
    ccfg = loop.LoopConfig(total_steps=12, ckpt_every=4,
                           ckpt_dir=str(tmp_path), log_every=100)
    plan = FaultPlan((FaultEvent(7, "leaf_death", 1),))
    p, _, sup = loop.run_supervised(_toy_step, dict(params0), None,
                                    _toy_factory, ccfg, plan,
                                    machine="tpu_v5e-256")
    assert sup.attempts == 2
    assert sup.recoveries[0]["resumed_from"] == 4
    assert sup.machine.n_alive == 255
    np.testing.assert_allclose(sup.losses, clean.losses, rtol=1e-6)
    assert sup.steps_run == 12


def test_supervised_restart_budget_exhausts(tmp_path):
    from repro.train import loop
    import jax.numpy as jnp
    cfg = loop.LoopConfig(total_steps=10, ckpt_every=4,
                          ckpt_dir=str(tmp_path), log_every=100)
    plan = FaultPlan((FaultEvent(2, "leaf_death", 0),
                      FaultEvent(5, "leaf_death", 1)))
    with pytest.raises(DeviceFailure):
        loop.run_supervised(_toy_step, {"w": jnp.ones((3,))}, None,
                            _toy_factory, cfg, plan, max_restarts=1)


def test_device_failure_carries_partial_trajectory():
    from repro.train import loop
    import jax.numpy as jnp
    cfg = loop.LoopConfig(total_steps=10, log_every=100)
    inj = FaultInjector(FaultPlan((FaultEvent(6, "leaf_death", 0),)))
    with pytest.raises(DeviceFailure) as exc_info:
        loop.run(_toy_step, {"w": jnp.ones((3,))}, None,
                 _toy_factory(0), cfg, injector=inj)
    assert len(exc_info.value.losses) == 6
    assert exc_info.value.start_step == 0
    assert exc_info.value.event.target == 0


def test_latest_step_skips_and_sweeps_tmp_dirs(tmp_path):
    """A crash mid-async-save leaves .tmp_<step> behind: it must never be
    counted as a checkpoint, and gc_tmp sweeps it on the restore path."""
    from repro.ckpt import checkpoint as ckpt
    ckpt.save(str(tmp_path), 4, {"w": np.ones(3)})
    orphan = tmp_path / ".tmp_8"
    orphan.mkdir()
    (orphan / "leaf_00000.npy").write_bytes(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert orphan.exists()                           # plain scan: kept
    assert ckpt.latest_step(str(tmp_path), gc_tmp=True) == 4
    assert not orphan.exists()                       # restore path: swept
    restored, step = ckpt.restore(str(tmp_path), {"w": np.zeros(3)})
    assert step == 4 and restored["w"].sum() == 3


def test_engine_chaos_matches_clean_run():
    """End-to-end serving acceptance: a real engine stream with one
    injected leaf death completes every request with survivor tokens
    bit-identical to the clean run, and reports the recovery."""
    import jax
    from repro import configs
    from repro.dist.sharding import lm_rules
    from repro.models import transformer as tr
    from repro.serving import EngineConfig, ServingEngine
    rules = lm_rules(())
    cfg = configs.get("qwen2-1.5b").smoke_config()
    params, _ = tr.init(jax.random.PRNGKey(0), cfg, rules)
    rng = np.random.default_rng(0)
    work = [(rng.integers(0, cfg.vocab, int(rng.integers(2, 7)),
                          dtype=np.int64).astype(np.int32),
             int(rng.integers(1, 5))) for _ in range(5)]

    def serve(injector=None):
        eng = ServingEngine(
            params, cfg, rules,
            EngineConfig(n_slots=2, page_size=4, n_pages=16,
                         max_pages_per_req=4, temperature=0.8, seed=0,
                         replace_every=0, place_devices=4),
            injector=injector)
        for prompt, gen in work:
            eng.submit(prompt, gen)
        return eng.run()

    clean = serve()
    plan = FaultPlan((FaultEvent(4, "leaf_death", 1),))
    chaos = serve(FaultInjector(plan))
    assert not chaos.failed
    gen_clean = {r["rid"]: r["generated"] for r in clean.requests}
    gen_chaos = {r["rid"]: r["generated"] for r in chaos.requests}
    assert gen_chaos == gen_clean
    assert chaos.recoveries and chaos.recoveries[0]["device"] == 1
    assert chaos.faults[0]["kind"] == "leaf_death"
    assert chaos.tokens_reprefilled >= 0
    assert "faults:" in chaos.summary()
