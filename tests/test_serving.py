"""Serving correctness core (ISSUE 7): paged-vs-dense decode equivalence
(the load-bearing test), page-table round trips, allocator free-list
accounting, placement invariance, map_pages, and engine determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.dist.sharding import lm_rules
from repro.launch.placement import PlacementSession
from repro.models import transformer as tr
from repro.serving import (EngineConfig, PagedKVCache, PagePoolExhausted,
                           ServingEngine)
from repro.serving.kv_cache import PageAllocator
from repro.serving.paged_decode import paged_decode_step

RULES = lm_rules(())


def _model(name="qwen2-1.5b"):
    arch = configs.get(name)
    cfg = arch.smoke_config()
    params, _ = tr.init(jax.random.PRNGKey(0), cfg, RULES)
    return cfg, params


def _pools(cfg, n_pages, page_size):
    shape = (cfg.n_layers, n_pages + 1, page_size, cfg.n_kv_heads,
             cfg.head_dim)
    return jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype)


# ---------------------------------------------------------------------------
# Allocator + page-table bookkeeping
# ---------------------------------------------------------------------------

def test_allocator_free_list_accounting():
    al = PageAllocator(8)
    a = al.alloc(3)
    b = al.alloc(2)
    assert al.n_free == 3
    assert len(set(a) | set(b)) == 5                 # disjoint
    al.free(a)
    assert al.n_free == 6
    c = al.alloc(3)
    assert set(c) == set(a)                          # LIFO reuse
    al.free(b)
    with pytest.raises(ValueError, match="double free"):
        al.free(b)
    with pytest.raises(PagePoolExhausted):
        al.alloc(al.n_free + 1)
    # a failed alloc must not leak pages
    before = al.n_free
    with pytest.raises(PagePoolExhausted):
        al.alloc(before + 1)
    assert al.n_free == before


def test_page_table_round_trip():
    cache = PagedKVCache(n_pages=12, page_size=4, n_slots=3,
                         max_pages_per_req=4)
    pages = cache.assign_slot(0, 10)                 # 3 pages
    assert len(pages) == 3
    row = cache.page_table[0]
    assert list(row[:3]) == pages and row[3] == cache.sentinel
    with pytest.raises(ValueError, match="already holds"):
        cache.assign_slot(0, 4)
    cache.assign_slot(1, 16)                         # 4 pages
    cache.check_invariants()
    freed = cache.release_slot(0)
    assert set(freed) == set(pages)
    assert (cache.page_table[0] == cache.sentinel).all()
    # alloc after free reuses the same physical pages
    again = cache.assign_slot(2, 10)
    assert set(again) == set(pages)
    cache.check_invariants()
    with pytest.raises(KeyError):
        cache.release_slot(0)                        # not held
    # capacity guards
    with pytest.raises(ValueError, match="max_pages_per_req"):
        cache.assign_slot(0, 100)
    assert not cache.can_admit(100)


def test_apply_placement_rewrites_all_bookkeeping():
    rng = np.random.default_rng(0)
    cache = PagedKVCache(n_pages=10, page_size=2, n_slots=2,
                         max_pages_per_req=5)
    cache.assign_slot(0, 6)
    cache.assign_slot(1, 4)
    cache.record_access({0: 6, 1: 4})
    before = cache.live_page_sets()
    asg = rng.integers(0, 3, 10)
    perm = cache.apply_placement(asg)
    cache.check_invariants()
    # device-contiguous: new labels sorted by device
    new_dev = np.empty(10, dtype=np.int64)
    new_dev[perm] = asg
    assert (np.diff(new_dev) >= 0).all()
    for slot, pages in before.items():
        assert cache.live_page_sets()[slot] == [int(perm[p])
                                                for p in pages]
    # traffic/access stats follow the relabeling (5 live pages, 1 step)
    assert cache.access_count.sum() == 5.0


# ---------------------------------------------------------------------------
# Paged-vs-dense decode equivalence (the load-bearing test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["qwen2-1.5b", "chatglm3-6b"])
def test_paged_equals_dense_decode(name):
    """Same tokens through the paged path (fragmented physical pages) and
    the dense decode_step: logits allclose at every position."""
    cfg, params = _model(name)
    B, T, page = 2, 10, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab)
    cache, _ = tr.init_cache(cfg, B, T, RULES)
    dense = jax.jit(lambda p, c, t, pos: tr.decode_step(p, c, t, pos, cfg,
                                                        RULES))
    n_pages = 16
    kp, vp = _pools(cfg, n_pages, page)
    pt = np.full((B, 3), n_pages, np.int32)
    pt[0] = [7, 2, 11]                               # deliberately
    pt[1] = [0, 9, 3]                                # fragmented
    paged = jax.jit(lambda p, k, v, t2, ln, t: paged_decode_step(
        p, k, v, t2, ln, t, cfg, RULES))
    c = cache
    for t in range(T - 1):
        lg_d, c = dense(params, c, toks[:, t:t + 1], jnp.int32(t))
        lg_p, kp, vp = paged(params, kp, vp, jnp.asarray(pt),
                             jnp.full((B,), t, jnp.int32),
                             toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                                   rtol=1e-5, atol=1e-5)


def test_paged_mixed_lengths_match_per_request_dense():
    """Continuous-batching regime: slots join at staggered steps, so the
    batch mixes positions; every slot's logits must match its own
    single-request dense decode."""
    cfg, params = _model()
    B, T, page, n_pages = 3, 8, 2, 24
    starts = [0, 2, 5]
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, (B, T)).astype(np.int32)
    dense = jax.jit(lambda p, c, t, pos: tr.decode_step(p, c, t, pos, cfg,
                                                        RULES))
    caches = [tr.init_cache(cfg, 1, T, RULES)[0] for _ in range(B)]
    kp, vp = _pools(cfg, n_pages, page)
    paged = jax.jit(lambda p, k, v, t2, ln, t: paged_decode_step(
        p, k, v, t2, ln, t, cfg, RULES))
    max_pages = T // page
    pt = np.full((B, max_pages), n_pages, np.int32)
    cache = PagedKVCache(n_pages, page, B, max_pages)
    pos = [0] * B
    for step in range(max(starts) + T):
        active = [b for b in range(B) if step >= starts[b] and pos[b] < T]
        if not active:
            break
        tokens = np.zeros((B, 1), np.int32)
        lengths = np.zeros((B,), np.int32)
        for b in active:
            if pos[b] == 0:
                pages = cache.assign_slot(b, T)
                pt[b, :len(pages)] = pages
            tokens[b, 0] = toks[b, pos[b]]
            lengths[b] = pos[b]
        lg_p, kp, vp = paged(params, kp, vp, jnp.asarray(pt),
                             jnp.asarray(lengths), jnp.asarray(tokens))
        for b in active:
            lg_d, caches[b] = dense(params, caches[b],
                                    jnp.asarray(toks[b:b + 1,
                                                     pos[b]:pos[b] + 1]),
                                    jnp.int32(pos[b]))
            np.testing.assert_allclose(np.asarray(lg_p[b]),
                                       np.asarray(lg_d[0]),
                                       rtol=1e-5, atol=1e-5)
            pos[b] += 1


def test_placement_permutation_preserves_logits():
    """apply_placement physically reorders the pool mid-stream; decode
    must not notice."""
    cfg, params = _model()
    B, T, page, n_pages = 2, 8, 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, T), 0, cfg.vocab)
    paged = jax.jit(lambda p, k, v, t2, ln, t: paged_decode_step(
        p, k, v, t2, ln, t, cfg, RULES))

    def run(with_placement):
        cache = PagedKVCache(n_pages, page, B, T // page, cfg=cfg)
        cache.assign_slot(0, T)
        cache.assign_slot(1, T)
        out = []
        for t in range(T - 1):
            lg, cache.k_pool, cache.v_pool = paged(
                params, cache.k_pool, cache.v_pool,
                jnp.asarray(cache.page_table),
                jnp.full((B,), t, jnp.int32), toks[:, t:t + 1])
            out.append(np.asarray(lg))
            if with_placement and t == 3:
                rng = np.random.default_rng(7)
                cache.apply_placement(rng.integers(0, 4, n_pages))
                cache.check_invariants()
        return out

    for a, b in zip(run(False), run(True)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_mla_cache_not_paged_yet():
    cfg = configs.get("deepseek-v2-lite-16b").smoke_config()
    with pytest.raises(NotImplementedError, match="MLA"):
        PagedKVCache(8, 4, 2, 4, cfg=cfg)


# ---------------------------------------------------------------------------
# map_pages (pages-as-rows placement entry)
# ---------------------------------------------------------------------------

def test_map_pages_groups_coaccessed_pages():
    """Two co-access cliques on 4 devices: the searched placement must
    beat round-robin scatter on makespan, and requests' cliques must not
    be cut more than scatter cuts them."""
    n = 16
    traffic = np.zeros((n, n))
    for lo in (0, 8):
        idx = np.arange(lo, lo + 8)
        traffic[np.ix_(idx, idx)] = 10.0
    np.fill_diagonal(traffic, 0.0)
    session = PlacementSession(cache_dir="")
    pl = session.map_pages(traffic, n_devices=4)
    assert pl.page_to_device.shape == (n,)
    assert pl.n_devices == 4
    from repro.core import baselines
    from repro.core.topology import guess_tree
    from repro.graph.graph import from_edges
    iu = np.triu_indices(n, 1)
    nz = traffic[iu] > 0
    g = from_edges(n, iu[0][nz], iu[1][nz],
                   traffic[iu][nz].astype(np.float32))
    topo = guess_tree(4)
    scatter = np.arange(n) % 4
    ours = baselines.score_all(g, topo, pl.page_to_device)["makespan"]
    theirs = baselines.score_all(g, topo, scatter)["makespan"]
    assert ours <= theirs
    # drift pricing: the scatter as `current` must read as drifted
    pl2 = session.map_pages(traffic, n_devices=4, current=scatter)
    assert pl2.drift_ratio >= 1.0


def test_map_pages_lints_malformed_traffic():
    bad = np.zeros((4, 4))
    bad[0, 1] = 1.0                                  # asymmetric
    with pytest.raises(ValueError, match="page-traffic"):
        PlacementSession(cache_dir="").map_pages(bad, n_devices=2)
    with pytest.raises(ValueError, match="machine or n_devices"):
        PlacementSession(cache_dir="").map_pages(np.zeros((4, 4)))


def test_map_pages_empty_epoch_gives_balanced_blocks():
    pl = PlacementSession(cache_dir="").map_pages(np.zeros((8, 8)),
                                                  n_devices=4)
    assert (np.bincount(pl.page_to_device, minlength=4) == 2).all()
    assert pl.makespan == 0.0


# ---------------------------------------------------------------------------
# Engine: determinism, completion, metrics
# ---------------------------------------------------------------------------

def _workload(cfg, n=6, seed=11):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab, int(rng.integers(2, 7)),
                          dtype=np.int64).astype(np.int32),
             int(rng.integers(1, 5))) for _ in range(n)]


def _run_engine(cfg, params, workload, **kw):
    defaults = dict(n_slots=2, page_size=4, n_pages=16,
                    max_pages_per_req=4, temperature=0.8, seed=0,
                    replace_every=0)
    defaults.update(kw)
    eng = ServingEngine(params, cfg, RULES, EngineConfig(**defaults))
    for prompt, gen in workload:
        eng.submit(prompt, gen)
    return eng.run()


def test_engine_deterministic_across_concurrency():
    """Sampling keys are (rid, pos) functions: the generated tokens are
    identical at different slot counts / batch compositions — the --seed
    bugfix, strengthened."""
    cfg, params = _model()
    work = _workload(cfg)
    r2 = _run_engine(cfg, params, work, n_slots=2)
    r4 = _run_engine(cfg, params, work, n_slots=4, n_pages=32)
    gen2 = {r["rid"]: r["generated"] for r in r2.requests}
    gen4 = {r["rid"]: r["generated"] for r in r4.requests}
    assert gen2 == gen4
    assert r4.steps <= r2.steps                      # more slots, no slower


def test_engine_completes_all_and_reports():
    cfg, params = _model()
    work = _workload(cfg, n=5, seed=3)
    rep = _run_engine(cfg, params, work, replace_every=6, place_devices=4)
    assert rep.n_requests == len(work)
    assert rep.tokens_out == sum(g for _, g in work)
    for r in rep.requests:
        # one token per step after admission: TTFT is exactly the prompt
        assert r["first_token_step"] - r["admit_step"] == (
            r["prompt_len"] - 1)
        assert len(r["generated"]) == r["max_new_tokens"]
    assert rep.placements, "re-placement policy never ran"
    assert rep.latency_steps_p99 >= rep.latency_steps_p50 > 0
    import json
    json.loads(rep.to_json())                        # trace round-trips


def test_engine_greedy_and_static_batching():
    cfg, params = _model()
    work = _workload(cfg, n=4, seed=9)
    cont = _run_engine(cfg, params, work, temperature=0.0)
    stat = _run_engine(cfg, params, work, temperature=0.0,
                       static_batching=True)
    # greedy sampling is scheduling-invariant too
    assert ({r["rid"]: r["generated"] for r in cont.requests}
            == {r["rid"]: r["generated"] for r in stat.requests})
    # continuous batching never takes more decode steps than static
    assert cont.steps <= stat.steps


def test_engine_infeasible_request_rejected_at_submit():
    cfg, params = _model()
    eng = ServingEngine(params, cfg, RULES,
                        EngineConfig(n_slots=1, page_size=2, n_pages=4,
                                     max_pages_per_req=4))
    with pytest.raises(ValueError, match="max_pages_per_req|never"):
        eng.submit(np.zeros(16, np.int32), 8)


def test_moe_config_paged_decode():
    """MoE layers (no MLA) go through the paged path: build a tiny moe
    GQA config and pin paged == dense."""
    base = configs.get("qwen2-1.5b").smoke_config()
    cfg = dataclasses.replace(base, moe=True, n_experts=4, n_shared=1,
                              top_k=2, d_ff_expert=32, n_dense_layers=1,
                              capacity_factor=64.0)
    params, _ = tr.init(jax.random.PRNGKey(0), cfg, RULES)
    B, T, page = 2, 6, 2
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, T), 0, cfg.vocab)
    cache, _ = tr.init_cache(cfg, B, T, RULES)
    kp, vp = _pools(cfg, 8, page)
    pt = np.asarray([[0, 1, 2], [5, 4, 3]], np.int32)
    dense = jax.jit(lambda p, c, t, pos: tr.decode_step(p, c, t, pos, cfg,
                                                        RULES))
    paged = jax.jit(lambda p, k, v, t2, ln, t: paged_decode_step(
        p, k, v, t2, ln, t, cfg, RULES))
    c = cache
    for t in range(T - 1):
        lg_d, c = dense(params, c, toks[:, t:t + 1], jnp.int32(t))
        lg_p, kp, vp = paged(params, kp, vp, jnp.asarray(pt),
                             jnp.full((B,), t, jnp.int32),
                             toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                                   rtol=2e-4, atol=2e-4)
