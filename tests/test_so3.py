"""Wigner-D recursion and equivariance of the eSCN machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.so3 import (block_diag_wigner, edge_rotation,
                              real_sph_harm, wigner_d_stack)


def _rand_rot(n, seed=0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(n, 3, 3)))
    return q * np.linalg.det(q)[:, None, None]


@pytest.mark.parametrize("l_max", [1, 2, 4, 6])
def test_orthogonality(l_max):
    d = np.asarray(block_diag_wigner(jnp.asarray(_rand_rot(8)), l_max))
    eye = np.eye(d.shape[-1])
    assert np.abs(d @ np.swapaxes(d, -1, -2) - eye).max() < 1e-5


@pytest.mark.parametrize("l_max", [2, 6])
def test_composition_homomorphism(l_max):
    q = _rand_rot(8, seed=1)
    d1 = np.asarray(block_diag_wigner(jnp.asarray(q[:4]), l_max))
    d2 = np.asarray(block_diag_wigner(jnp.asarray(q[4:]), l_max))
    d12 = np.asarray(block_diag_wigner(jnp.asarray(q[:4] @ q[4:]), l_max))
    assert np.abs(d12 - d1 @ d2).max() < 1e-5


@pytest.mark.parametrize("l_max", [1, 3, 6])
def test_rotates_real_spherical_harmonics(l_max):
    """Y(R r) = D(R) Y(r) — the defining property."""
    q = _rand_rot(8, seed=2)
    rng = np.random.default_rng(3)
    r = rng.normal(size=(8, 3))
    r /= np.linalg.norm(r, axis=-1, keepdims=True)
    d = np.asarray(block_diag_wigner(jnp.asarray(q), l_max))
    lhs = real_sph_harm(np.einsum("bij,bj->bi", q, r), l_max)
    rhs = np.einsum("bmn,bn->bm", d, real_sph_harm(r, l_max))
    assert np.abs(lhs - rhs).max() < 1e-5


def test_edge_rotation_aligns_to_z():
    rng = np.random.default_rng(4)
    d = rng.normal(size=(64, 3))
    d /= np.linalg.norm(d, axis=-1, keepdims=True)
    d = np.concatenate([d, [[0, 0, 1.0], [0, 0, -1.0], [1e-8, 0, 1.0]]])
    r = np.asarray(edge_rotation(jnp.asarray(d)))
    z = np.einsum("bij,bj->bi", r, d)
    assert np.abs(z - np.asarray([0, 0, 1.0])).max() < 1e-5
    assert np.abs(np.linalg.det(r) - 1).max() < 1e-5


def test_equiformer_invariance_and_chunking():
    """Rotating all positions leaves the (scalar-readout) logits invariant;
    the chunked edge path matches the direct path exactly."""
    from repro.configs.common import smoke_gnn_batch
    from repro.dist.sharding import gnn_rules
    from repro.models import equiformer as eq

    rules = gnn_rules(())
    batch_np = smoke_gnn_batch(n=48, deg=4, d_feat=8, n_classes=4,
                               with_pos=True)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    cfg = eq.EquiformerConfig(name="t", n_layers=2, channels=16, l_max=3,
                              m_max=2, n_heads=4, d_in=8, n_classes=4)
    p, _ = eq.init(jax.random.PRNGKey(0), cfg, rules)
    logits = eq.forward(p, batch, cfg, rules)
    assert not bool(jnp.isnan(logits).any())

    q = jnp.asarray(_rand_rot(1, seed=5)[0], jnp.float32)
    rot = dict(batch)
    rot["pos"] = batch["pos"] @ q.T
    logits_rot = eq.forward(p, rot, cfg, rules)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_rot),
                               atol=2e-4)

    import dataclasses
    cfg_c = dataclasses.replace(cfg, edge_chunk=37)
    logits_c = eq.forward(p, batch, cfg_c, rules)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_c),
                               atol=2e-4)
