"""End-to-end behaviour: models actually LEARN on the synthetic pipelines
(loss decreases over a few dozen steps), and the partitioner-driven
placement path runs end to end on a GNN training job."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.mapping import apply_placement, block_placement
from repro.core.partitioner import PartitionConfig, partition
from repro.core.topology import balanced_tree
from repro.data import pipeline
from repro.dist.sharding import gnn_rules, lm_rules, recsys_rules
from repro.graph.generators import rmat
from repro.optim import adamw
from repro.train.steps import make_train_step


def _run(step, params, opt, batches, n):
    losses = []
    step = jax.jit(step)
    for _ in range(n):
        params, opt, m = step(params, opt, next(batches))
        losses.append(float(m["loss"]))
    return losses, params


def test_lm_learns():
    from repro.models import transformer as tr
    cfg = configs.get("qwen2-1.5b").smoke_config()
    rules = lm_rules(())
    params, _ = tr.init(jax.random.PRNGKey(0), cfg, rules)
    ocfg = adamw.AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=5)
    opt = adamw.init(params, ocfg)
    step = make_train_step(lambda p, b: tr.loss_fn(p, b, cfg, rules), ocfg)

    def batches():
        for b in pipeline.lm_batches(cfg.vocab, 8, 32, seed=0):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    losses, _ = _run(step, params, opt, batches(), 50)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::10]


def test_gnn_learns_with_partitioner_placement():
    """Full paper-integrated path: partition the graph with the makespan
    objective, permute rows into bin blocks, train on the permuted graph."""
    from repro.models import gnn
    g = rmat(400, 2400, seed=0)
    topo = balanced_tree((2, 2), F=0.5)
    res = partition(g, topo, PartitionConfig(seed=0))
    pl = block_placement(res.part, topo.k)
    g2 = apply_placement(g, pl)

    feats = pipeline.gnn_features(g, 16, 4, seed=0)
    n_pad = pl.n_pad
    x = np.zeros((n_pad, 16), np.float32)
    x[pl.perm] = feats["x"]
    labels = np.zeros(n_pad, np.int32)
    labels[pl.perm] = feats["labels"]
    mask = np.zeros(n_pad, np.float32)
    mask[pl.perm] = 1.0
    batch = {"x": jnp.asarray(x), "labels": jnp.asarray(labels),
             "label_mask": jnp.asarray(mask),
             "senders": jnp.asarray(g2.senders),
             "receivers": jnp.asarray(g2.receivers),
             "edge_weight": jnp.asarray(g2.edge_weight),
             "degrees": jnp.asarray(g2.degrees().astype(np.float32))}

    cfg = gnn.GNNConfig(name="t", kind="gin", n_layers=2, d_hidden=32,
                        d_in=16, n_classes=4)
    rules = gnn_rules(())
    params, _ = gnn.init(jax.random.PRNGKey(0), cfg, rules)
    ocfg = adamw.AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=0)
    opt = adamw.init(params, ocfg)
    step = make_train_step(lambda p, b: gnn.loss_fn(p, b, cfg, rules), ocfg)

    def batches():
        while True:
            yield batch

    losses, _ = _run(step, params, opt, batches(), 40)
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_recsys_learns():
    from repro.models import recsys as rs
    cfg = configs.get("two-tower-retrieval").smoke_config()
    rules = recsys_rules(())
    params, _ = rs.init(jax.random.PRNGKey(0), cfg, rules)
    ocfg = adamw.AdamWConfig(lr=1e-2, total_steps=80, warmup_steps=5,
                             weight_decay=0.0)
    opt = adamw.init(params, ocfg)
    step = make_train_step(lambda p, b: rs.loss_fn(p, b, cfg, rules), ocfg)

    def batches():
        for b in pipeline.recsys_batches(cfg.n_items, cfg.n_cats, 32,
                                         cfg.hist_len, cfg.d_dense, seed=0):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    losses, _ = _run(step, params, opt, batches(), 60)
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), losses[::15]
